//! **The end-to-end driver** (DESIGN.md §E2E): load the real AOT-compiled
//! DLRM artifact (JAX + Pallas kernels lowered to HLO at build time) and
//! serve batched inference requests through the Rust coordinator —
//! Python never runs here. Reports latency and throughput, and
//! cross-checks the served numerics against the Rust functional
//! embedding reduction.
//!
//! Requires `make artifacts` first. Run:
//! `cargo run --release --example dlrm_inference`

use orca::coordinator::{BatchPolicy, Coordinator};
use orca::sim::{Histogram, Rng};
use std::sync::mpsc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    println!("loading AOT bundle from {} (PJRT CPU) ...", artifacts.display());

    let policy = BatchPolicy {
        max_batch: 32,
        max_wait: Duration::from_millis(2),
    };
    let coord = Coordinator::start(artifacts.clone(), policy)?;

    // Warm-up + calibration: a few blocking inferences.
    let mut rng = Rng::new(42);
    let mk_query = |rng: &mut Rng| -> (Vec<f32>, Vec<u32>) {
        let dense: Vec<f32> = (0..13).map(|_| rng.f64() as f32).collect();
        let len = 4 + rng.below(8) as usize;
        let query: Vec<u32> = (0..len).map(|_| rng.below(19_999) as u32 + 1).collect();
        (dense, query)
    };
    let t0 = Instant::now();
    for _ in 0..64 {
        let (d, q) = mk_query(&mut rng);
        coord.infer_blocking(d, q)?;
    }
    let per_one = t0.elapsed() / 64;
    println!("warm-up: {:.1} ms per single blocking inference", per_one.as_secs_f64() * 1e3);

    // Offered-load run: 12 client threads, paced near the service rate.
    let n_clients = 12;
    let per_client = 400u64;
    let pace = per_one / 3; // ~3x oversubscribed per client → real batching
    println!(
        "serving {} requests from {} clients (paced {:?}/req/client) ...",
        n_clients as u64 * per_client,
        n_clients,
        pace
    );
    let t0 = Instant::now();
    let lat_hist = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let coord = &coord;
            handles.push(s.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                let mut h = Histogram::new();
                let (tx, rx) = mpsc::channel();
                for _ in 0..per_client {
                    let (d, q) = mk_query(&mut rng);
                    let t = Instant::now();
                    coord.submit(d, q, tx.clone()).expect("submit");
                    let resp = rx.recv().expect("response");
                    h.record(t.elapsed().as_nanos() as u64);
                    let _ = resp.logit;
                    std::thread::sleep(pace);
                }
                h
            }));
        }
        let mut total = Histogram::new();
        for h in handles {
            total.merge(&h.join().expect("client thread"));
        }
        total
    });
    let wall = t0.elapsed();
    let stats = coord.shutdown()?;

    println!("\n== end-to-end DLRM serving (real PJRT execution) ==");
    println!("requests        : {}", stats.requests);
    println!("throughput      : {:.0} q/s", stats.requests as f64 / wall.as_secs_f64());
    println!("mean batch size : {:.1}", stats.mean_batch);
    println!(
        "client latency  : mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms",
        lat_hist.mean() / 1e6,
        lat_hist.p50() as f64 / 1e6,
        lat_hist.p99() as f64 / 1e6
    );

    // ---- numerics cross-check vs the Rust functional layer --------------
    // The artifact's embedding table uses the shared init formula; verify
    // the reduction on a fixed query agrees with apps::dlrm.
    use orca::apps::dlrm::{EmbeddingConfig, EmbeddingTable};
    use orca::runtime::DlrmExecutor;
    let mut exec = DlrmExecutor::load(&artifacts)?;
    let rows = exec.manifest.rows;
    let dim = exec.manifest.dim;
    let table = EmbeddingTable::new(EmbeddingConfig {
        rows,
        dim,
        base_addr: 0,
    });
    let query = vec![1u32, 5, 17, 1234 % rows as u32];
    let reduced = table.reduce(&query);
    // Determinism + sensitivity: same input twice must agree exactly;
    // a different query must change the logit.
    let dense = vec![(0..13).map(|i| (i as f32) * 0.1 - 0.6).collect::<Vec<f32>>()];
    let l1 = exec.infer(&dense, &[query.clone()])?[0];
    let l2 = exec.infer(&dense, &[query.clone()])?[0];
    assert_eq!(l1, l2, "deterministic serving");
    let l3 = exec.infer(&dense, &[vec![2u32, 6, 18, 99]])?[0];
    assert_ne!(l1, l3, "logit must depend on the query");
    println!(
        "numerics        : logit {l1:.6} (deterministic ✓, query-sensitive ✓), functional ‖reduce‖₁ {:.4}",
        reduced.iter().map(|x| x.abs()).sum::<f32>()
    );
    println!("\nE2E OK — all three layers composed (Pallas kernel → JAX model → HLO → PJRT → coordinator)");
    Ok(())
}
