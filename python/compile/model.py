"""L2: the DLRM forward pass in JAX, calling the L1 Pallas kernels.

Architecture (facebook DLRM [117], MERCI-scale config):

    dense (batch, 13) ──bottom MLP (Pallas)──► (batch, 64)
    indices (batch, L) ──embedding reduce (Pallas)──► (batch, 64)
    dot-interaction + concat ──► (batch, 65)
    top MLP (Pallas) ──► (batch, 1) click logit

Parameters are *runtime inputs* (not baked constants) so the HLO text
stays small and the Rust runtime feeds them from `dlrm_params.bin`.
Row 0 of the embedding table is reserved as the all-zero padding row;
queries shorter than L pad with index 0.
"""

from functools import partial

import jax.numpy as jnp
import numpy as np

from .kernels import embedding, mlp, ref

# Model hyperparameters (MERCI defaults: dim 64; DLRM: 13 dense features).
N_DENSE = 13
DIM = 64
DEFAULT_ROWS = 100_000
DEFAULT_LOOKUPS = 32

PARAM_NAMES = [
    "table",
    "w_bot0",
    "b_bot0",
    "w_bot1",
    "b_bot1",
    "w_top0",
    "b_top0",
    "w_top1",
    "b_top1",
]


def param_shapes(rows: int = DEFAULT_ROWS, dim: int = DIM, n_dense: int = N_DENSE):
    """Shapes (in PARAM_NAMES order) — the contract with the Rust runtime."""
    return {
        "table": (rows, dim),
        "w_bot0": (n_dense, dim),
        "b_bot0": (dim,),
        "w_bot1": (dim, dim),
        "b_bot1": (dim,),
        "w_top0": (dim + 1, dim),
        "b_top0": (dim,),
        "w_top1": (dim, 1),
        "b_top1": (1,),
    }


def init_params(rows: int = DEFAULT_ROWS, dim: int = DIM, n_dense: int = N_DENSE, seed: int = 0):
    """Deterministic init. The embedding table uses the shared shader-hash
    formula (cross-checked against Rust); weights use a seeded RNG with
    Xavier-ish scaling. Row 0 of the table is zeroed (padding row)."""
    rng = np.random.RandomState(seed)
    shapes = param_shapes(rows, dim, n_dense)
    params = {}
    table = ref.init_table(rows, dim)
    table[0, :] = 0.0
    params["table"] = table
    for name, shape in shapes.items():
        if name == "table":
            continue
        if name.startswith("w_"):
            fan_in = shape[0]
            params[name] = (rng.randn(*shape) / np.sqrt(fan_in)).astype(np.float32)
        else:
            params[name] = np.zeros(shape, np.float32)
    return params


def forward(params, dense_in, indices, *, use_pallas: bool = True):
    """The served computation. `params` is a dict of arrays (traced as
    inputs when jitted via `forward_flat`)."""
    if use_pallas:
        x = mlp.mlp_layer(dense_in, params["w_bot0"], params["b_bot0"], relu=True, bn=DIM)
        x = mlp.mlp_layer(x, params["w_bot1"], params["b_bot1"], relu=True, bn=DIM)
        reduced = embedding.reduce_gather(params["table"], indices)
    else:
        x = ref.mlp_layer(dense_in, params["w_bot0"], params["b_bot0"])
        x = ref.mlp_layer(x, params["w_bot1"], params["b_bot1"])
        reduced = ref.embedding_reduce(params["table"], indices)
    z = ref.feature_interaction(x, reduced)  # small concat: plain jnp (L2)
    if use_pallas:
        z = mlp.mlp_layer(z, params["w_top0"], params["b_top0"], relu=True, bn=DIM)
        z = mlp.mlp_layer(z, params["w_top1"], params["b_top1"], relu=False, bn=1)
    else:
        z = ref.mlp_layer(z, params["w_top0"], params["b_top0"])
        z = ref.mlp_layer(z, params["w_top1"], params["b_top1"], relu=False)
    return (z[:, 0],)


def forward_flat(*args, use_pallas: bool = True):
    """Flat-argument version for AOT lowering: args are
    (dense, indices, *params-in-PARAM_NAMES-order). Returns a 1-tuple
    (lowered with return_tuple=True; the Rust side unwraps to_tuple1)."""
    dense_in, indices = args[0], args[1]
    params = dict(zip(PARAM_NAMES, args[2:]))
    return forward(params, dense_in, indices, use_pallas=use_pallas)


def make_forward(use_pallas: bool = True):
    return partial(forward_flat, use_pallas=use_pallas)


def pad_indices(queries, lookups: int = DEFAULT_LOOKUPS):
    """Pad/truncate variable-length queries to (batch, lookups) with the
    zero padding row."""
    batch = len(queries)
    out = np.zeros((batch, lookups), np.int32)
    for i, q in enumerate(queries):
        q = list(q)[:lookups]
        out[i, : len(q)] = q
    return out
