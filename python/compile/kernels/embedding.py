"""L1 Pallas kernel: embedding gather-reduce — the DLRM hot spot (§IV-C).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's APU
keeps 64 memory requests in flight against host/HBM memory; on TPU the
equivalent schedule is expressed with a grid over batch blocks whose
BlockSpec stages the index block into VMEM while the accumulator stays
VMEM-resident. Two implementations:

* ``reduce_gather`` — scalar-indexed row loads accumulated in VMEM
  (the direct analogue of the APU's gather engine);
* ``reduce_onehot`` — one-hot × table matmul, which maps the reduction
  onto the MXU systolic array (profitable when ``lookups`` is large and
  the row block is resident).

Both are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls; see /opt/xla-example/README.md) and validated against
``ref.embedding_reduce``.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch-block size: 8 queries per grid step keeps the VMEM
# footprint at 8*(L*4 + dim*4) + 8*dim*4 bytes — ~18 KB at L=64, dim=64.
DEFAULT_BLOCK_B = 8


def _gather_kernel(idx_ref, table_ref, out_ref, *, lookups: int):
    """One grid step: reduce `lookups` rows for a block of queries."""
    block_b = out_ref.shape[0]
    dim = out_ref.shape[1]

    def body(j, acc):
        def row_for(i, acc):
            idx = idx_ref[i, j]
            row = table_ref[idx, :]
            return acc.at[i].add(row)

        return jax.lax.fori_loop(0, block_b, row_for, acc)

    acc = jnp.zeros((block_b, dim), jnp.float32)
    acc = jax.lax.fori_loop(0, lookups, body, acc)
    out_ref[...] = acc


def reduce_gather(table: jnp.ndarray, indices: jnp.ndarray, block_b: int = DEFAULT_BLOCK_B):
    """Gather-reduce via scalar row loads.

    table:   (rows, dim) f32 — stays in ANY/HBM; rows are fetched on
             demand (the HBM→VMEM stream the APU does over UPI/DDR).
    indices: (batch, lookups) i32; batch must be a multiple of block_b
             (callers pad).
    """
    batch, lookups = indices.shape
    rows, dim = table.shape
    assert batch % block_b == 0, f"batch {batch} % block_b {block_b} != 0"
    grid = (batch // block_b,)
    return pl.pallas_call(
        partial(_gather_kernel, lookups=lookups),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, lookups), lambda b: (b, 0)),
            pl.BlockSpec((rows, dim), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, dim), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dim), jnp.float32),
        interpret=True,
    )(indices, table)


def _onehot_kernel(idx_ref, table_ref, out_ref, *, rows: int):
    """One grid step: one-hot(indices) @ table on the MXU."""
    idx = idx_ref[...]  # (block_b, L)
    # (block_b, L, rows) one-hot contracted against (rows, dim).
    oh = jax.nn.one_hot(idx, rows, dtype=jnp.float32)  # (block_b, L, rows)
    counts = oh.sum(axis=1)  # (block_b, rows) — multiplicity per row
    out_ref[...] = counts @ table_ref[...]


def reduce_onehot(table: jnp.ndarray, indices: jnp.ndarray, block_b: int = DEFAULT_BLOCK_B):
    """Gather-reduce as a matmul (MXU mapping). O(rows) work per query —
    only sensible for small tables / ablation purposes."""
    batch, lookups = indices.shape
    rows, dim = table.shape
    assert batch % block_b == 0
    grid = (batch // block_b,)
    return pl.pallas_call(
        partial(_onehot_kernel, rows=rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, lookups), lambda b: (b, 0)),
            pl.BlockSpec((rows, dim), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, dim), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dim), jnp.float32),
        interpret=True,
    )(indices, table)


def vmem_bytes(block_b: int, lookups: int, dim: int) -> int:
    """Static VMEM footprint of one ``reduce_gather`` grid step (§Perf):
    the staged index block, the accumulator, and one in-flight row."""
    idx_block = block_b * lookups * 4
    acc = block_b * dim * 4
    row = dim * 4
    return idx_block + acc + row
