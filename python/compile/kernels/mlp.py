"""L1 Pallas kernel: fused dense layer (matmul + bias + ReLU).

Tiled for the MXU: grid over (batch tiles × output tiles); each step
keeps an (bm, K) activation stripe and a (K, bn) weight tile in VMEM and
writes one (bm, bn) output tile. The DLRM MLPs are small (K ≤ 65), so a
full-K stripe fits trivially; the tiling still exercises the BlockSpec
schedule that matters at scale. ``interpret=True`` as everywhere.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def mlp_layer(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    relu: bool = True,
    bm: int = 8,
    bn: int = 128,
):
    """Fused x @ w + b (+ReLU). batch must divide by bm; out-dim tiles of
    bn (clamped to the actual width)."""
    batch, k = x.shape
    k2, out = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    assert batch % bm == 0, f"batch {batch} % bm {bm} != 0"
    bn = min(bn, out)
    # Pad out-dim to a multiple of bn via a single tile when small.
    assert out % bn == 0 or out == bn, f"out {out} % bn {bn} != 0"
    grid = (batch // bm, max(out // bn, 1))
    return pl.pallas_call(
        partial(_mlp_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, out), jnp.float32),
        interpret=True,
    )(x, w, b)


def mxu_utilization_estimate(bm: int, k: int, bn: int) -> float:
    """Fraction of a 128×128 MXU pass doing useful work for one tile —
    the §Perf proxy we report in DESIGN.md (interpret mode has no real
    TPU timing). Rows feed the systolic array over bm cycles, the
    contraction dim fills k of 128 PE columns; bn only lengthens the
    pass, so it does not appear."""
    return min(bm / 128.0, 1.0) * min(k / 128.0, 1.0)
