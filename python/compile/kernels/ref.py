"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Everything here is straight-line jax.numpy with no Pallas, no custom
calls — the ground truth that `test_kernel.py` checks the kernels
against, and the numerical contract shared with the Rust functional
layer (`rust/src/apps/dlrm/embedding.rs` uses the same `init_table`
formula, asserted by the cross-check test vectors).
"""

import jax.numpy as jnp
import numpy as np


def init_table(rows: int, dim: int) -> np.ndarray:
    """Deterministic table init shared with Rust.

    value(r, d) = frac(sin(r*12.9898 + d*78.233) * 43758.5453) - 0.5
    with frac(x) = x - floor(x).
    """
    r = np.arange(rows, dtype=np.float64)[:, None]
    d = np.arange(dim, dtype=np.float64)[None, :]
    x = r * 12.9898 + d * 78.233
    v = np.sin(x) * 43758.5453
    s = v - np.floor(v)
    return (s - 0.5).astype(np.float32)


def embedding_reduce(table: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """Sum-reduce embedding rows.

    table:   (rows, dim) f32
    indices: (batch, lookups) i32 — per-query feature ids
    returns: (batch, dim) f32
    """
    gathered = table[indices]  # (batch, lookups, dim)
    return gathered.sum(axis=1)


def mlp_layer(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, relu: bool = True) -> jnp.ndarray:
    """One dense layer: x @ w + b, optional ReLU.

    x: (batch, in), w: (in, out), b: (out,)
    """
    y = x @ w + b
    return jnp.maximum(y, 0.0) if relu else y


def feature_interaction(dense: jnp.ndarray, reduced: jnp.ndarray) -> jnp.ndarray:
    """DLRM dot-interaction between the bottom-MLP output and the reduced
    embedding, concatenated with the dense features (the 2-source special
    case of DLRM's pairwise interaction).

    dense:   (batch, dim)
    reduced: (batch, dim)
    returns: (batch, dim + 1)
    """
    dot = jnp.sum(dense * reduced, axis=1, keepdims=True)
    return jnp.concatenate([dense, dot], axis=1)


def dlrm_forward(params, dense_in, indices):
    """Full reference DLRM forward pass.

    params: dict with keys
        table (rows, dim),
        w_bot0/b_bot0 (dense_in->dim), w_bot1/b_bot1 (dim->dim),
        w_top0/b_top0 (dim+1->dim), w_top1/b_top1 (dim->1)
    dense_in: (batch, n_dense) f32
    indices:  (batch, lookups) i32
    returns:  (batch,) click logits
    """
    x = mlp_layer(dense_in, params["w_bot0"], params["b_bot0"])
    x = mlp_layer(x, params["w_bot1"], params["b_bot1"])
    reduced = embedding_reduce(params["table"], indices)
    z = feature_interaction(x, reduced)
    z = mlp_layer(z, params["w_top0"], params["b_top0"])
    z = mlp_layer(z, params["w_top1"], params["b_top1"], relu=False)
    return z[:, 0]
