"""AOT lowering: JAX/Pallas DLRM → HLO **text** artifacts for the Rust
runtime.

Interchange format is HLO text, NOT serialized HloModuleProto — jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md and gen_hlo.py).

Outputs (under --out-dir, default ../artifacts):
    dlrm_b{B}.hlo.txt     one module per batch size B
    dlrm_params.bin       all parameters, f32 LE, concatenated in
                          PARAM_NAMES order
    dlrm_manifest.txt     the Rust-side contract: model dims, input
                          order/shapes, per-param byte offsets

Run via `make artifacts` (no-op when inputs are unchanged).
"""

import argparse
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_batch(batch: int, rows: int, lookups: int, use_pallas: bool) -> str:
    shapes = model.param_shapes(rows)
    dense = jax.ShapeDtypeStruct((batch, model.N_DENSE), np.float32)
    idx = jax.ShapeDtypeStruct((batch, lookups), np.int32)
    params = [
        jax.ShapeDtypeStruct(shapes[n], np.float32) for n in model.PARAM_NAMES
    ]
    fn = model.make_forward(use_pallas)
    lowered = jax.jit(fn).lower(dense, idx, *params)
    return to_hlo_text(lowered)


def write_params(out_dir: str, rows: int) -> dict:
    params = model.init_params(rows)
    offsets = {}
    path = os.path.join(out_dir, "dlrm_params.bin")
    off = 0
    with open(path, "wb") as f:
        for name in model.PARAM_NAMES:
            arr = np.ascontiguousarray(params[name], dtype=np.float32)
            offsets[name] = (off, arr.shape)
            f.write(arr.tobytes())
            off += arr.nbytes
    return offsets


def write_manifest(out_dir: str, rows: int, lookups: int, batches, offsets):
    path = os.path.join(out_dir, "dlrm_manifest.txt")
    with open(path, "w") as f:
        f.write(f"n_dense {model.N_DENSE}\n")
        f.write(f"dim {model.DIM}\n")
        f.write(f"rows {rows}\n")
        f.write(f"lookups {lookups}\n")
        f.write(f"batches {' '.join(str(b) for b in batches)}\n")
        for name in model.PARAM_NAMES:
            off, shape = offsets[name]
            dims = "x".join(str(d) for d in shape)
            f.write(f"param {name} {dims} {off}\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--rows", type=int, default=20_000,
                    help="embedding rows in the served artifact (default sized for a fast e2e demo)")
    ap.add_argument("--lookups", type=int, default=32)
    ap.add_argument("--batches", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference instead of the Pallas kernels (ablation)")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    for b in args.batches:
        text = lower_batch(b, args.rows, args.lookups, use_pallas=not args.no_pallas)
        path = os.path.join(out_dir, f"dlrm_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    offsets = write_params(out_dir, args.rows)
    write_manifest(out_dir, args.rows, args.lookups, args.batches, offsets)
    print(f"wrote params + manifest under {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
