"""L2 correctness: DLRM forward shapes, Pallas-vs-reference equivalence,
padding semantics, parameter contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

ROWS = 512


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in model.init_params(rows=ROWS).items()}


def inputs(batch, lookups, seed=0):
    rng = np.random.RandomState(seed)
    dense = jnp.asarray(rng.randn(batch, model.N_DENSE).astype(np.float32))
    idx = jnp.asarray(rng.randint(1, ROWS, size=(batch, lookups), dtype=np.int32))
    return dense, idx


class TestForward:
    def test_output_shape_and_finite(self, params):
        dense, idx = inputs(8, 16)
        (logits,) = model.forward(params, dense, idx, use_pallas=True)
        assert logits.shape == (8,)
        assert np.isfinite(np.asarray(logits)).all()

    def test_pallas_matches_reference_path(self, params):
        dense, idx = inputs(16, 24, seed=1)
        (a,) = model.forward(params, dense, idx, use_pallas=True)
        (b,) = model.forward(params, dense, idx, use_pallas=False)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_reference_path_matches_ref_dlrm(self, params):
        dense, idx = inputs(8, 8, seed=2)
        (a,) = model.forward(params, dense, idx, use_pallas=False)
        b = ref.dlrm_forward(params, dense, idx)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(batch_blocks=st.integers(1, 3), lookups=st.integers(1, 40), seed=st.integers(0, 1000))
    def test_pallas_equivalence_swept(self, params, batch_blocks, lookups, seed):
        dense, idx = inputs(batch_blocks * 8, lookups, seed=seed)
        (a,) = model.forward(params, dense, idx, use_pallas=True)
        (b,) = model.forward(params, dense, idx, use_pallas=False)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestPadding:
    def test_pad_row_is_zero(self, params):
        assert float(jnp.abs(params["table"][0]).max()) == 0.0

    def test_padding_does_not_change_logits(self, params):
        dense, idx = inputs(8, 8, seed=3)
        # Same queries, padded out to 16 lookups with the zero row.
        idx_padded = jnp.concatenate([idx, jnp.zeros((8, 8), jnp.int32)], axis=1)
        (a,) = model.forward(params, dense, idx, use_pallas=False)
        (b,) = model.forward(params, dense, idx_padded, use_pallas=False)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_pad_indices_helper(self):
        out = model.pad_indices([[1, 2, 3], [4]], lookups=5)
        np.testing.assert_array_equal(
            out, np.asarray([[1, 2, 3, 0, 0], [4, 0, 0, 0, 0]], np.int32)
        )
        # Truncation.
        out = model.pad_indices([list(range(10))], lookups=4)
        np.testing.assert_array_equal(out, np.asarray([[0, 1, 2, 3]], np.int32))


class TestParamContract:
    def test_shapes_cover_all_names(self):
        shapes = model.param_shapes(rows=ROWS)
        assert set(shapes) == set(model.PARAM_NAMES)

    def test_flat_forward_matches_dict_forward(self, params):
        dense, idx = inputs(8, 8, seed=4)
        flat = [params[n] for n in model.PARAM_NAMES]
        (a,) = model.forward_flat(dense, idx, *flat, use_pallas=False)
        (b,) = model.forward(params, dense, idx, use_pallas=False)
        np.testing.assert_allclose(a, b)

    def test_init_is_deterministic(self):
        a = model.init_params(rows=64, seed=7)
        b = model.init_params(rows=64, seed=7)
        for n in model.PARAM_NAMES:
            np.testing.assert_array_equal(a[n], b[n])
