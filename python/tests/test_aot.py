"""AOT path: HLO-text lowering, params.bin layout, manifest contract.

The full `make artifacts` run is exercised end-to-end by the Rust
integration test (`rust/tests/runtime_roundtrip.rs`); here we check the
pieces cheaply with a tiny model.
"""

import os
import subprocess
import sys

import numpy as np

from compile import aot, model


def test_lower_produces_hlo_text(tmp_path):
    text = aot.lower_batch(batch=8, rows=64, lookups=4, use_pallas=False)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Params are runtime inputs: 2 data inputs + 9 params.
    assert text.count("parameter(") >= 11

def test_pallas_lowering_also_produces_hlo_text():
    text = aot.lower_batch(batch=8, rows=64, lookups=4, use_pallas=True)
    assert "HloModule" in text
    # interpret=True must leave no Mosaic custom-calls behind.
    assert "mosaic" not in text.lower()


def test_params_bin_layout(tmp_path):
    out = str(tmp_path)
    offsets = aot.write_params(out, rows=64)
    path = os.path.join(out, "dlrm_params.bin")
    blob = np.fromfile(path, dtype=np.float32)
    params = model.init_params(rows=64)
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert blob.size == total
    # Spot-check: the table occupies [0, rows*dim) and matches init.
    rows_dim = 64 * model.DIM
    np.testing.assert_array_equal(blob[:rows_dim], params["table"].ravel())
    # Offsets are contiguous in PARAM_NAMES order.
    expected_off = 0
    for name in model.PARAM_NAMES:
        off, shape = offsets[name]
        assert off == expected_off
        expected_off += int(np.prod(shape)) * 4


def test_manifest_format(tmp_path):
    out = str(tmp_path)
    offsets = aot.write_params(out, rows=64)
    aot.write_manifest(out, rows=64, lookups=8, batches=[8], offsets=offsets)
    lines = open(os.path.join(out, "dlrm_manifest.txt")).read().splitlines()
    kv = dict(l.split(None, 1) for l in lines if not l.startswith("param"))
    assert kv["rows"] == "64"
    assert kv["lookups"] == "8"
    params = [l.split() for l in lines if l.startswith("param")]
    assert len(params) == len(model.PARAM_NAMES)
    # param table 64x64 0
    assert params[0][1] == "table"
    assert params[0][2] == f"64x{model.DIM}"
    assert params[0][3] == "0"


def test_cli_end_to_end_tiny(tmp_path):
    env = dict(os.environ)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--rows", "64", "--lookups", "4", "--batches", "8"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr
    for f in ["dlrm_b8.hlo.txt", "dlrm_params.bin", "dlrm_manifest.txt"]:
        assert os.path.exists(os.path.join(str(tmp_path), f)), f
