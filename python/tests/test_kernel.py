"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute layer: hypothesis
sweeps shapes/dtypes-adjacent parameters and asserts allclose against
the reference on every draw.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import embedding, mlp, ref


def make_table(rows, dim):
    return jnp.asarray(ref.init_table(rows, dim))


class TestEmbeddingReduceGather:
    def test_matches_ref_basic(self):
        table = make_table(512, 64)
        idx = jnp.asarray(np.random.RandomState(0).randint(0, 512, size=(16, 24), dtype=np.int32))
        got = embedding.reduce_gather(table, idx)
        want = ref.embedding_reduce(table, idx)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(8, 300),
        dim=st.sampled_from([4, 8, 16, 32, 64]),
        batch_blocks=st.integers(1, 4),
        lookups=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_swept(self, rows, dim, batch_blocks, lookups, seed):
        block_b = embedding.DEFAULT_BLOCK_B
        batch = batch_blocks * block_b
        table = make_table(rows, dim)
        idx = jnp.asarray(
            np.random.RandomState(seed).randint(0, rows, size=(batch, lookups), dtype=np.int32)
        )
        got = embedding.reduce_gather(table, idx)
        want = ref.embedding_reduce(table, idx)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_duplicate_indices(self):
        table = make_table(32, 8)
        idx = jnp.asarray(np.full((8, 6), 7, dtype=np.int32))
        got = embedding.reduce_gather(table, idx)
        want = 6.0 * table[7][None, :].repeat(8, axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_misaligned_batch(self):
        table = make_table(32, 8)
        idx = jnp.zeros((5, 4), jnp.int32)  # 5 % 8 != 0
        with pytest.raises(AssertionError):
            embedding.reduce_gather(table, idx)

    def test_vmem_budget_within_design_target(self):
        # DESIGN.md §Perf: ≤ 4 MB per grid step at (8, 64, dim 64).
        assert embedding.vmem_bytes(8, 64, 64) <= 4 << 20


class TestEmbeddingReduceOnehot:
    @settings(max_examples=10, deadline=None)
    @given(
        rows=st.integers(8, 128),
        dim=st.sampled_from([4, 16, 64]),
        lookups=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, rows, dim, lookups, seed):
        table = make_table(rows, dim)
        idx = jnp.asarray(
            np.random.RandomState(seed).randint(0, rows, size=(8, lookups), dtype=np.int32)
        )
        got = embedding.reduce_onehot(table, idx)
        want = ref.embedding_reduce(table, idx)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_variants_agree_with_each_other(self):
        table = make_table(64, 16)
        idx = jnp.asarray(np.random.RandomState(3).randint(0, 64, size=(8, 12), dtype=np.int32))
        a = embedding.reduce_gather(table, idx)
        b = embedding.reduce_onehot(table, idx)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestMlpKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        batch_blocks=st.integers(1, 4),
        k=st.integers(1, 96),
        out=st.sampled_from([1, 16, 64, 128]),
        relu=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_swept(self, batch_blocks, k, out, relu, seed):
        rng = np.random.RandomState(seed)
        batch = batch_blocks * 8
        x = jnp.asarray(rng.randn(batch, k).astype(np.float32))
        w = jnp.asarray(rng.randn(k, out).astype(np.float32))
        b = jnp.asarray(rng.randn(out).astype(np.float32))
        got = mlp.mlp_layer(x, w, b, relu=relu, bm=8, bn=min(128, out))
        want = ref.mlp_layer(x, w, b, relu=relu)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_relu_clamps_negatives(self):
        x = jnp.asarray([[-1.0, 2.0]] * 8, jnp.float32)
        w = jnp.eye(2, dtype=jnp.float32)
        b = jnp.zeros(2, jnp.float32)
        got = mlp.mlp_layer(x, w, b, relu=True, bm=8, bn=2)
        np.testing.assert_allclose(got, jnp.asarray([[0.0, 2.0]] * 8))

    def test_no_relu_passes_negatives(self):
        x = jnp.asarray([[-1.0, 2.0]] * 8, jnp.float32)
        w = jnp.eye(2, dtype=jnp.float32)
        b = jnp.zeros(2, jnp.float32)
        got = mlp.mlp_layer(x, w, b, relu=False, bm=8, bn=2)
        np.testing.assert_allclose(got, x)

    def test_mxu_estimate_monotone(self):
        assert mlp.mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mlp.mxu_utilization_estimate(8, 64, 64) < 1.0


class TestSharedInitFormula:
    def test_rust_crosscheck_vector(self):
        # Mirrors rust/src/apps/dlrm/embedding.rs::test_vector_for_python_crosscheck
        table = ref.init_table(100, 8)
        out = table[[0, 1, 2, 50, 99], 0].sum()
        want = sum(
            float(ref.init_table(100, 8)[r, 0]) for r in [0, 1, 2, 50, 99]
        )
        assert abs(out - want) < 1e-6

    def test_values_centered_in_unit_interval(self):
        t = ref.init_table(1000, 4)
        assert t.min() >= -0.5 and t.max() <= 0.5
        assert abs(float(t.mean())) < 0.02

    def test_deterministic(self):
        a = ref.init_table(50, 8)
        b = ref.init_table(50, 8)
        np.testing.assert_array_equal(a, b)
